"""Sharded, fault-tolerant checkpointing (npz shards + JSON manifest).

Design (orbax-style, dependency-free):
  * Every leaf is saved as its own ``.npy`` file named by its tree path hash;
    a JSON manifest maps path -> (file, shape, dtype) plus user metadata
    (step, loader state, mesh shape at save time).
  * Writes go to ``step_<n>.tmp/`` and are atomically renamed to ``step_<n>/``
    only after the manifest is fsynced — a crash mid-save never corrupts the
    latest complete checkpoint.
  * ``CheckpointManager`` runs saves on a background thread (training never
    blocks on disk), keeps the newest ``keep`` checkpoints, and on restore
    picks the newest *complete* step.
  * Elastic restore: arrays are saved unsharded (gathered); ``load`` places
    them onto whatever mesh/sharding the restoring job provides — a run saved
    at N devices restores at M (repro.runtime.elastic tests this).

Multi-host note: on a real cluster each host saves only the shards it owns
(``process_index`` prefix) — here single-process saves the full array; the
format is forward-compatible (manifest carries a ``host`` field).
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np

from repro.utils.tree import flatten_with_names

_MANIFEST = "manifest.json"

_NATIVE = {np.dtype(t) for t in (
    "bool", "int8", "uint8", "int16", "uint16", "int32", "uint32", "int64",
    "uint64", "float16", "float32", "float64")}


def _restore_dtype(arr: np.ndarray, dtype: str) -> np.ndarray:
    """Undo the byte-view applied to non-native dtypes at save time."""
    if np.dtype(arr.dtype) in _NATIVE and str(arr.dtype) == dtype:
        return arr
    import ml_dtypes  # ships with jax
    target = np.dtype(getattr(ml_dtypes, dtype, dtype))
    return arr.reshape(arr.shape[:-1] + (-1,)).view(target)[..., 0] \
        if arr.dtype == np.uint8 else arr.astype(target)


def _leaf_file(name: str) -> str:
    return hashlib.sha1(name.encode()).hexdigest()[:16] + ".npy"


def save_checkpoint(directory: str, step: int, tree: Any,
                    metadata: dict | None = None) -> str:
    """Atomic checkpoint write. Returns the final checkpoint path."""
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    manifest: dict[str, Any] = {
        "step": step, "host": 0, "metadata": metadata or {}, "leaves": {}}
    for name, leaf in flatten_with_names(tree):
        arr = np.asarray(jax.device_get(leaf))
        fn = _leaf_file(name)
        dtype = str(arr.dtype)
        if arr.dtype not in _NATIVE:   # bf16/fp8: npy can't round-trip them
            arr = arr.view(np.uint8).reshape(arr.shape + (arr.dtype.itemsize,))
        np.save(os.path.join(tmp, fn), arr)
        manifest["leaves"][name] = {
            "file": fn, "shape": list(leaf.shape), "dtype": dtype}
    mpath = os.path.join(tmp, _MANIFEST)
    with open(mpath, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)        # atomic publish
    return final


def latest_step(directory: str) -> int | None:
    """Newest *complete* step (has a manifest) in the directory."""
    if not os.path.isdir(directory):
        return None
    steps = []
    for d in os.listdir(directory):
        if d.startswith("step_") and not d.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, d, _MANIFEST)):
                steps.append(int(d[len("step_"):]))
    return max(steps) if steps else None


def load_checkpoint(directory: str, tree_like: Any, step: int | None = None,
                    shardings: Any = None) -> tuple[Any, dict]:
    """Restore into the structure of ``tree_like``.

    ``shardings``: optional pytree of NamedSharding (elastic restore onto a
    different mesh); None keeps arrays on the default device.
    Returns (tree, metadata).
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no complete checkpoint under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, _MANIFEST)) as f:
        manifest = json.load(f)

    names = dict(flatten_with_names(tree_like))
    shard_map = dict(flatten_with_names(shardings)) if shardings is not None \
        else {}
    loaded = {}
    for name in names:
        ent = manifest["leaves"].get(name)
        if ent is None:
            raise KeyError(f"checkpoint {path} missing leaf {name!r}")
        arr = np.load(os.path.join(path, ent["file"]))
        arr = _restore_dtype(arr, ent["dtype"])
        assert list(arr.shape) == ent["shape"], (name, arr.shape, ent["shape"])
        sh = shard_map.get(name)
        loaded[name] = jax.device_put(arr, sh) if sh is not None else arr

    leaves_names = [n for n, _ in flatten_with_names(tree_like)]
    flat = [loaded[n] for n in leaves_names]
    tree = jax.tree.unflatten(jax.tree.structure(tree_like), flat)
    return tree, manifest["metadata"]


class CheckpointManager:
    """Async save + retention. ``save()`` returns immediately."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._lock = threading.Lock()
        self._pending: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    def save(self, step: int, tree: Any, metadata: dict | None = None,
             blocking: bool = False) -> None:
        # materialize on the calling thread (device_get under jit is not
        # thread-safe against donation); disk IO happens in the background.
        host_tree = jax.tree.map(lambda a: np.asarray(jax.device_get(a)), tree)

        def work():
            with self._lock:
                save_checkpoint(self.directory, step, host_tree, metadata)
                self._gc()

        self.wait()
        t = threading.Thread(target=work, daemon=True)
        t.start()
        self._pending = t
        if blocking:
            self.wait()

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def restore(self, tree_like: Any, step: int | None = None,
                shardings: Any = None) -> tuple[Any, dict]:
        self.wait()
        return load_checkpoint(self.directory, tree_like, step, shardings)

    def _gc(self) -> None:
        steps = sorted(
            int(d[len("step_"):]) for d in os.listdir(self.directory)
            if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(
                os.path.join(self.directory, f"step_{s:08d}"),
                ignore_errors=True)
